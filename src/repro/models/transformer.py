"""Decoder-only transformer family: dense GQA, MLA, and MoE variants.

Covers the five assigned LM architectures (deepseek-v2-lite, granite-moe,
minicpm3, command-r, phi4-mini). Design notes:

- **scan over layers** — one traced layer, stacked params ``[L, ...]``;
  essential for compile time at 512 fake devices;
- **GSPMD sharding** — params TP-sharded over ``model``; activations
  batch-sharded over ``('pod','data')``; MoE experts EP-sharded over
  ``model`` with an explicit ``shard_map`` token exchange (the same
  bucketed all_to_all as the DDSL shuffle);
- **MLA** (DeepSeek-V2 §2.1) — low-rank Q/KV projections; the KV cache
  stores only ``(c_kv, k_rope)`` (kv_lora + rope dims per position);
  decode can run in the *absorbed* formulation (queries projected into
  latent space — a §Perf iteration) or the materialized one;
- **attention** — ``kernels.ops.flash_attention`` with backend "ref" for
  dry-run lowering (chunked over queries to bound memory) or the Pallas
  kernel on TPU;
- **serve modes** — ``prefill`` builds the cache with chunked causal
  attention; ``decode_step`` appends one token at position ``pos``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.dist.collectives import routed_exchange
from repro.kernels import ops

from .common import DEFAULT_DTYPE, apply_rope, cross_entropy, data_axes, rms_norm, rope, shard

__all__ = ["TransformerConfig", "init_params", "forward", "prefill", "prefill_chunked", "decode_step", "param_specs", "init_cache"]


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    attn: str = "gqa"              # "gqa" | "mla"
    # MLA
    q_lora: int = 0
    kv_lora: int = 0
    qk_nope: int = 0
    qk_rope: int = 0
    v_head: int = 0
    # MoE
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    n_shared: int = 0
    d_expert: int = 0
    first_dense: int = 0           # leading dense layers before MoE layers
    rope_theta: float = 1e4
    dtype: str = "bfloat16"
    attn_backend: str = "ref"
    q_chunk: int = 256             # ref-attention query chunk (bounds score HBM)
    moe_capacity_factor: float = 2.0
    decode_absorbed: bool = False  # MLA absorbed decode (§Perf iteration)
    attn_seq_shard: bool = False   # REFUTED §Perf iter: GSPMD re-gathers K/V (see EXPERIMENTS.md)
    remat: bool = True

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def n_experts_padded(self) -> int:
        """Expert arrays padded to a multiple of the max EP width (16) so
        shard_map splits evenly; dummy experts are never routed to."""
        ep_max = 16
        return ((self.n_experts + ep_max - 1) // ep_max) * ep_max

    @property
    def n_moe_layers(self) -> int:
        return self.n_layers - self.first_dense if self.moe else 0

    @property
    def n_dense_layers(self) -> int:
        return self.n_layers if not self.moe else self.first_dense

    def param_count(self) -> int:
        """Total parameters (for 6·N·D roofline bookkeeping)."""
        total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(
            jax.eval_shape(lambda: init_params(self, jax.random.PRNGKey(0)))
        ))
        return total

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if not self.moe:
            return self.param_count()
        full = self.param_count()
        expert_params = 3 * self.d_model * self.d_expert
        inactive = self.n_moe_layers * (self.n_experts_padded - self.top_k) * expert_params
        return full - inactive


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def _dense_layer_shapes(c: TransformerConfig) -> Dict[str, Tuple[int, ...]]:
    d, f = c.d_model, c.d_ff
    shapes = {
        "attn_norm": (d,),
        "mlp_norm": (d,),
        "wg": (d, f),
        "wu": (d, f),
        "wd": (f, d),
    }
    shapes.update(_attn_shapes(c))
    return shapes


def _attn_shapes(c: TransformerConfig) -> Dict[str, Tuple[int, ...]]:
    d = c.d_model
    if c.attn == "gqa":
        return {
            "wq": (d, c.n_heads * c.d_head),
            "wk": (d, c.n_kv_heads * c.d_head),
            "wv": (d, c.n_kv_heads * c.d_head),
            "wo": (c.n_heads * c.d_head, d),
        }
    qdim = c.n_heads * (c.qk_nope + c.qk_rope)
    shapes = {
        "wkv_a": (d, c.kv_lora + c.qk_rope),
        "kv_norm": (c.kv_lora,),
        "wk_b": (c.kv_lora, c.n_heads * c.qk_nope),
        "wv_b": (c.kv_lora, c.n_heads * c.v_head),
        "wo": (c.n_heads * c.v_head, d),
    }
    if c.q_lora:
        shapes.update({"wq_a": (d, c.q_lora), "q_norm": (c.q_lora,), "wq_b": (c.q_lora, qdim)})
    else:
        shapes.update({"wq": (d, qdim)})
    return shapes


def _moe_layer_shapes(c: TransformerConfig) -> Dict[str, Tuple[int, ...]]:
    d, fe = c.d_model, c.d_expert
    shapes = {
        "attn_norm": (d,),
        "mlp_norm": (d,),
        "router": (d, c.n_experts),
        "e_wg": (c.n_experts_padded, d, fe),
        "e_wu": (c.n_experts_padded, d, fe),
        "e_wd": (c.n_experts_padded, fe, d),
    }
    if c.n_shared:
        fs = c.n_shared * fe
        shapes.update({"s_wg": (d, fs), "s_wu": (d, fs), "s_wd": (fs, d)})
    shapes.update(_attn_shapes(c))
    return shapes


def init_params(c: TransformerConfig, key: jax.Array) -> Dict:
    dt = c.jdtype

    def make(shapes: Dict[str, Tuple[int, ...]], n: int, key) -> Dict:
        out = {}
        for i, (name, shp) in enumerate(sorted(shapes.items())):
            k = jax.random.fold_in(key, i)
            if name.endswith("norm"):
                out[name] = jnp.ones((n,) + shp, dt)
            else:
                fan_in = shp[-2] if len(shp) >= 2 else shp[-1]
                out[name] = (jax.random.normal(k, (n,) + shp, jnp.float32) / math.sqrt(fan_in)).astype(dt)
        return out

    params = {
        "embed": (jax.random.normal(jax.random.fold_in(key, 1), (c.vocab, c.d_model), jnp.float32) * 0.02).astype(dt),
        "final_norm": jnp.ones((c.d_model,), dt),
        "lm_head": (jax.random.normal(jax.random.fold_in(key, 2), (c.d_model, c.vocab), jnp.float32) / math.sqrt(c.d_model)).astype(dt),
    }
    if c.n_dense_layers:
        params["dense"] = make(_dense_layer_shapes(c), c.n_dense_layers, jax.random.fold_in(key, 3))
    if c.n_moe_layers:
        params["moe"] = make(_moe_layer_shapes(c), c.n_moe_layers, jax.random.fold_in(key, 4))
    return params


def param_specs(c: TransformerConfig, mesh_axes: Sequence[str]) -> Dict:
    """TP over 'model'; embeddings vocab-sharded; experts EP over 'model'."""
    mdl = "model" if "model" in mesh_axes else None

    def dense_specs(shapes):
        out = {}
        for name in shapes:
            if name.endswith("norm"):
                out[name] = P(None, None)
            elif name in ("wg", "wu", "wq", "wk", "wv", "wq_a", "wq_b", "wkv_a", "wk_b", "wv_b", "s_wg", "s_wu"):
                out[name] = P(None, None, mdl)
            elif name in ("wd", "wo", "s_wd"):
                out[name] = P(None, mdl, None)
            elif name == "router":
                out[name] = P(None, None, None)
            elif name.startswith("e_"):
                out[name] = P(None, mdl, None, None)
            else:
                out[name] = P(None, None, None)
        return out

    specs = {
        "embed": P(mdl, None),
        "final_norm": P(None),
        "lm_head": P(None, mdl),
    }
    if c.n_dense_layers:
        specs["dense"] = dense_specs(_dense_layer_shapes(c))
    if c.n_moe_layers:
        specs["moe"] = dense_specs(_moe_layer_shapes(c))
    return specs


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------

def _attention(q, k, v, c: TransformerConfig, *, q_offset, causal: bool = True):
    """q: [B, Hq, Lq, Dh]; ref backend chunks queries to bound memory.

    ``q_offset`` may be a traced scalar (decode position); the Pallas
    kernel requires a static offset, so traced offsets use the ref path.
    """
    if c.attn_backend != "ref" and isinstance(q_offset, int):
        return ops.flash_attention(q, k, v, causal=causal, q_offset=q_offset, backend=c.attn_backend)
    b, hq, lq, dh = q.shape
    hkv, lk = k.shape[1], k.shape[2]
    dv = v.shape[-1]  # MLA: value dim ≠ query/key dim
    group = hq // hkv
    scale = 1.0 / math.sqrt(dh)
    chunk = min(c.q_chunk, lq)
    n_chunks = max(1, lq // chunk)
    if lq % chunk:
        n_chunks += 1
        pad = n_chunks * chunk - lq
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qs = q.reshape(b, hkv, group, n_chunks, chunk, dh).transpose(3, 0, 1, 2, 4, 5)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    # §Perf: shard the score tensor over 'model' along the KV axis when
    # divisible — softmax then reduces across shards (small all-reduce)
    # instead of materializing B·H·q·S scores per device.
    seq_spec = None
    if c.attn_seq_shard:
        try:
            am = jax.typeof(q).sharding.mesh  # abstract mesh inside jit
            if "model" in am.axis_names and lk % am.shape["model"] == 0:
                seq_spec = P(None, None, None, None, "model")
        except Exception:
            seq_spec = None

    def one_chunk(ci, qc):
        logits = jnp.einsum("bhgqd,bhkd->bhgqk", qc.astype(jnp.float32), kf) * scale
        if seq_spec is not None:
            logits = shard(logits, seq_spec)
        if causal:
            qpos = ci * chunk + jnp.arange(chunk)[:, None] + q_offset
            kpos = jnp.arange(lk)[None, :]
            logits = jnp.where((kpos <= qpos)[None, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        return jnp.einsum("bhgqk,bhkd->bhgqd", probs, vf)

    # remat per chunk: the bwd recomputes each chunk's probs instead of
    # keeping every chunk's score tensor live for the layer backward.
    one_chunk = jax.checkpoint(one_chunk, prevent_cse=False)
    out = jax.lax.map(lambda args: one_chunk(*args), (jnp.arange(n_chunks), qs))
    out = out.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, n_chunks * chunk, dv)
    return out[:, :, :lq].astype(q.dtype)


def _gqa_qkv(lp, x, c: TransformerConfig, positions):
    b, l, _ = x.shape
    q = jnp.einsum("bld,dh->blh", x, lp["wq"]).reshape(b, l, c.n_heads, c.d_head)
    k = jnp.einsum("bld,dh->blh", x, lp["wk"]).reshape(b, l, c.n_kv_heads, c.d_head)
    v = jnp.einsum("bld,dh->blh", x, lp["wv"]).reshape(b, l, c.n_kv_heads, c.d_head)
    cos, sin = rope(positions, c.d_head, c.rope_theta)
    q = apply_rope(q.transpose(0, 2, 1, 3), cos, sin)
    k = apply_rope(k.transpose(0, 2, 1, 3), cos, sin)
    return q, k, v.transpose(0, 2, 1, 3)


def _mla_q(lp, x, c: TransformerConfig, positions):
    b, l, _ = x.shape
    if c.q_lora:
        cq = rms_norm(jnp.einsum("bld,dr->blr", x, lp["wq_a"]), lp["q_norm"])
        q = jnp.einsum("blr,rh->blh", cq, lp["wq_b"])
    else:
        q = jnp.einsum("bld,dh->blh", x, lp["wq"])
    q = q.reshape(b, l, c.n_heads, c.qk_nope + c.qk_rope).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., : c.qk_nope], q[..., c.qk_nope :]
    cos, sin = rope(positions, c.qk_rope, c.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    return q_nope, q_rope


def _mla_kv_latent(lp, x, c: TransformerConfig, positions):
    """Compressed cache entries: (c_kv [B,L,kv_lora], k_rope [B,L,qk_rope])."""
    kv = jnp.einsum("bld,dr->blr", x, lp["wkv_a"])
    c_kv = rms_norm(kv[..., : c.kv_lora], lp["kv_norm"])
    k_rope = kv[..., c.kv_lora :]
    cos, sin = rope(positions, c.qk_rope, c.rope_theta)
    k_rope = apply_rope(k_rope[:, None], cos, sin)[:, 0]
    return c_kv, k_rope


def _mla_attention(lp, q_nope, q_rope, c_kv, k_rope, c: TransformerConfig, q_offset):
    """Materialized MLA: expand K/V from the latent cache."""
    b, h, lq, _ = q_nope.shape
    lk = c_kv.shape[1]
    k_nope = jnp.einsum("blr,rh->blh", c_kv, lp["wk_b"]).reshape(b, lk, h, c.qk_nope).transpose(0, 2, 1, 3)
    v = jnp.einsum("blr,rh->blh", c_kv, lp["wv_b"]).reshape(b, lk, h, c.v_head).transpose(0, 2, 1, 3)
    k_rope_b = jnp.broadcast_to(k_rope[:, None], (b, h, lk, c.qk_rope))
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate([k_nope, k_rope_b], axis=-1)
    return _attention(q, k, v, c, q_offset=q_offset)


def _mla_attention_absorbed(lp, q_nope, q_rope, c_kv, k_rope, c: TransformerConfig, q_offset):
    """Absorbed MLA decode: score directly against the latent cache.

    q_nope is projected through ``wk_bᵀ`` into latent space; attention runs
    over ``c_kv`` (kv_lora dims) + shared rope channel; values are read in
    latent space and expanded once per *query* instead of per cache entry.
    Cuts decode FLOPs/bytes from O(L·h·(nope+v)) to O(L·(kv_lora+rope)).
    """
    b, h, lq, _ = q_nope.shape
    wk_b = lp["wk_b"].reshape(c.kv_lora, h, c.qk_nope)
    q_lat = jnp.einsum("bhqn,rhn->bhqr", q_nope, wk_b)        # [B,H,Lq,kv_lora]
    scale = 1.0 / math.sqrt(c.qk_nope + c.qk_rope)
    logits = (
        jnp.einsum("bhqr,blr->bhql", q_lat.astype(jnp.float32), c_kv.astype(jnp.float32))
        + jnp.einsum("bhqe,ble->bhql", q_rope.astype(jnp.float32), k_rope.astype(jnp.float32))
    ) * scale
    lk = c_kv.shape[1]
    qpos = jnp.arange(lq)[:, None] + q_offset
    kpos = jnp.arange(lk)[None, :]
    logits = jnp.where((kpos <= qpos)[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    o_lat = jnp.einsum("bhql,blr->bhqr", probs, c_kv.astype(jnp.float32))  # latent values
    wv_b = lp["wv_b"].reshape(c.kv_lora, h, c.v_head)
    return jnp.einsum("bhqr,rhv->bhqv", o_lat, wv_b.astype(jnp.float32)).astype(c_kv.dtype)


# ---------------------------------------------------------------------------
# MoE block (shard_map token routing over the 'model' axis)
# ---------------------------------------------------------------------------

def _moe_ffn(lp, x, c: TransformerConfig, mesh: Optional[Mesh]):
    """x: [B, L, D] → routed expert SwiGLU + shared experts."""
    b, l, d = x.shape
    router_logits = jnp.einsum("bld,de->ble", x, lp["router"]).astype(jnp.float32)
    weights, sel = jax.lax.top_k(jax.nn.softmax(router_logits, axis=-1), c.top_k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)

    if mesh is None or "model" not in mesh.axis_names:
        # single-device fallback: dense gather loop over experts
        out = jnp.zeros_like(x)
        flat = x.reshape(-1, d)
        fs = sel.reshape(-1, c.top_k)
        fw = weights.reshape(-1, c.top_k)
        for e in range(c.n_experts):
            mask = (fs == e).astype(x.dtype) * fw.astype(x.dtype)   # [T, k]
            coef = mask.sum(-1)                                     # [T]
            g = jnp.einsum("td,df->tf", flat, lp["e_wg"][e])
            u = jnp.einsum("td,df->tf", flat, lp["e_wu"][e])
            y = jnp.einsum("tf,fd->td", jax.nn.silu(g) * u, lp["e_wd"][e])
            out += (y * coef[:, None]).reshape(b, l, d)
    else:
        out = _moe_routed(lp, x, sel, weights, c, mesh)

    if c.n_shared:
        g = jnp.einsum("bld,df->blf", x, lp["s_wg"])
        u = jnp.einsum("bld,df->blf", x, lp["s_wu"])
        out = out + jnp.einsum("blf,fd->bld", jax.nn.silu(g) * u, lp["s_wd"])
    return out


def _moe_routed(lp, x, sel, weights, c: TransformerConfig, mesh: Mesh):
    """EP dispatch: bucketed all_to_all over 'model', ragged grouped GEMM."""
    ep = mesh.shape["model"]
    e_per = c.n_experts_padded // ep
    daxes = data_axes(mesh.axis_names)
    b, l, d = x.shape
    dsize = 1
    for a in daxes:
        dsize *= mesh.shape[a]
    if b % max(dsize, 1) != 0:
        daxes = ()  # tiny decode batches replicate across data
    # §Perf A.4: without sequence-splitting, all EP peers hold identical
    # tokens and route them redundantly — 16× duplicated expert compute
    # and dispatch bytes (measured useful_ratio 0.02 on v2-lite). Split
    # the token dim over 'model' whenever it divides; tiny decode steps
    # (l=1) keep the replicated path (waste is bounded by one token).
    seq_axis = "model" if l % ep == 0 else None

    def body(xb, selb, wb, wg, wu, wd):
        # local shard: [b_loc, l, d]; experts wg: [e_per, d, fe]
        bl, ll, _ = xb.shape
        t = bl * ll
        flat = xb.reshape(t, d)
        sel_f = selb.reshape(t, c.top_k)
        w_f = wb.reshape(t, c.top_k)
        rows = jnp.repeat(flat, c.top_k, axis=0)
        expert = sel_f.reshape(-1)
        wcol = w_f.reshape(-1)
        targets = (expert // e_per).astype(jnp.int32)
        valid = jnp.ones_like(targets, dtype=bool)
        # capacity per *active* shard (padding may leave trailing shards idle)
        ep_active = max(1, -(-c.n_experts // e_per))
        cap = max(1, int(t * c.top_k * c.moe_capacity_factor) // ep_active)
        (r_rows, r_expert), r_valid, restore, ovf = routed_exchange(
            [rows, expert.astype(jnp.int32)], targets, valid, "model", ep, cap
        )
        # park invalid rows in the last group: they are zero rows, produce
        # zero outputs, and are masked again below — never silent garbage.
        local_e = jnp.where(r_valid, r_expert % e_per, e_per - 1)
        order = jnp.argsort(local_e, stable=True)
        xs = r_rows[order]
        le = local_e[order]
        sizes = jnp.bincount(le, length=e_per)
        offsets = jnp.concatenate([jnp.zeros(1, sizes.dtype), jnp.cumsum(sizes)[:-1]])
        # Expert-windowed dense GEMMs (§Perf iteration): ragged_dot lowers
        # to dense [e_per, rows, d] temporaries (6 GiB each on v2-lite);
        # a static window of 2× the expected per-expert load keeps the
        # working set at [window, d_ff] with buffers reused across the
        # e_per loop. Rows past the window are masked (capacity semantics
        # at expert granularity), never silently mangled.
        total = xs.shape[0]
        window = min(total, max(128, (2 * total) // e_per))
        y = jnp.zeros((total, d), xs.dtype)
        for e in range(e_per):
            start = jnp.clip(offsets[e].astype(jnp.int32), 0, total - window)
            xe = jax.lax.dynamic_slice(xs, (start, jnp.int32(0)), (window, d))
            idx = start + jnp.arange(window, dtype=jnp.int32)
            emask = (idx >= offsets[e]) & (idx < offsets[e] + sizes[e])
            ge = jnp.einsum("wd,df->wf", xe, wg[e])
            ue = jnp.einsum("wd,df->wf", xe, wu[e])
            ye = jnp.einsum("wf,fd->wd", (jax.nn.silu(ge) * ue).astype(xe.dtype), wd[e])
            ye = jnp.where(emask[:, None], ye, 0)
            y = y.at[idx].add(ye, mode="drop")
        y = jnp.where(r_valid[order][:, None], y, 0)
        y = y[jnp.argsort(order, stable=True)]                     # unsort
        back = restore(y)                                          # [t*k, d]
        out = (back * wcol[:, None].astype(back.dtype)).reshape(t, c.top_k, d).sum(1)
        return out.reshape(bl, ll, d)

    return jax.shard_map(
        body,
        mesh=mesh,
        in_specs=(
            P(daxes if daxes else None, seq_axis, None),
            P(daxes if daxes else None, seq_axis, None),
            P(daxes if daxes else None, seq_axis, None),
            P("model", None, None), P("model", None, None), P("model", None, None),
        ),
        out_specs=P(daxes if daxes else None, seq_axis, None),
        check_vma=False,
    )(x, sel, weights, lp["e_wg"], lp["e_wu"], lp["e_wd"])


# ---------------------------------------------------------------------------
# Layers + model
# ---------------------------------------------------------------------------

def _layer(lp, x, c: TransformerConfig, positions, mesh, *, moe: bool, cache=None, pos=None):
    """One transformer block.

    ``cache``: per-layer latent tensors when serving; ``pos``: write index
    of the incoming chunk (queries occupy absolute positions pos..pos+Lq-1,
    so the causal mask with ``q_offset = pos`` also hides the not-yet-
    written zero entries beyond the newest token).
    """
    h = rms_norm(x, lp["attn_norm"])

    if c.attn == "gqa":
        q, k, v = _gqa_qkv(lp, h, c, positions)
        if cache is not None:
            ck, cv = cache
            k = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, pos, 0))
            v = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, pos, 0))
            attn = _attention(q, k, v, c, q_offset=pos)
            new_cache = (k, v)
        else:
            attn = _attention(q, k, v, c, q_offset=0)
            new_cache = None
        attn = attn.transpose(0, 2, 1, 3).reshape(h.shape[0], h.shape[1], -1)
        x = x + jnp.einsum("blh,hd->bld", attn, lp["wo"])
    else:
        q_nope, q_rope = _mla_q(lp, h, c, positions)
        c_kv, k_rope = _mla_kv_latent(lp, h, c, positions)
        if cache is not None:
            cc, cr = cache
            c_kv = jax.lax.dynamic_update_slice(cc, c_kv.astype(cc.dtype), (0, pos, 0))
            k_rope = jax.lax.dynamic_update_slice(cr, k_rope.astype(cr.dtype), (0, pos, 0))
            if c.decode_absorbed and q_nope.shape[2] == 1:
                attn = _mla_attention_absorbed(lp, q_nope, q_rope, c_kv, k_rope, c, pos)
            else:
                attn = _mla_attention(lp, q_nope, q_rope, c_kv, k_rope, c, pos)
            new_cache = (c_kv, k_rope)
        else:
            attn = _mla_attention(lp, q_nope, q_rope, c_kv, k_rope, c, 0)
            new_cache = None
        attn = attn.transpose(0, 2, 1, 3).reshape(h.shape[0], h.shape[1], -1)
        x = x + jnp.einsum("blh,hd->bld", attn, lp["wo"])

    h2 = rms_norm(x, lp["mlp_norm"])
    if moe:
        x = x + _moe_ffn(lp, h2, c, mesh)
    else:
        g = jnp.einsum("bld,df->blf", h2, lp["wg"])
        u = jnp.einsum("bld,df->blf", h2, lp["wu"])
        x = x + jnp.einsum("blf,fd->bld", jax.nn.silu(g) * u, lp["wd"])
    return x, new_cache


def _run_layers(params, x, c: TransformerConfig, positions, mesh, caches=None, pos=None):
    """Scan dense layers then MoE layers (stacked params)."""
    new_caches = {}

    def run_group(x, group, moe, cache_group):
        stacked = params[group]
        if cache_group is None:
            def step(xc, lp):
                out, _ = _layer(lp, xc, c, positions, mesh, moe=moe)
                return out, 0
            if c.remat:
                step = jax.checkpoint(step, prevent_cse=False)
            x, _ = jax.lax.scan(step, x, stacked)
            return x, None

        ck, cv = cache_group

        def step(xc, inp):
            lp, k_l, v_l = inp
            out, new_cache = _layer(
                lp, xc, c, positions, mesh, moe=moe, cache=(k_l, v_l), pos=pos
            )
            return out, (new_cache[0], new_cache[1])

        if c.remat:
            step = jax.checkpoint(step, prevent_cse=False)
        x, (nk, nv) = jax.lax.scan(step, x, (stacked, ck, cv))
        return x, (nk, nv)

    if c.n_dense_layers:
        x, nc = run_group(x, "dense", False, None if caches is None else caches["dense"])
        if nc is not None:
            new_caches["dense"] = nc
    if c.n_moe_layers:
        x, nc = run_group(x, "moe", True, None if caches is None else caches["moe"])
        if nc is not None:
            new_caches["moe"] = nc
    return x, (new_caches if caches is not None else None)


def forward(params, tokens, c: TransformerConfig, mesh: Optional[Mesh] = None):
    """Training/teacher-forcing forward: tokens [B, S] → logits [B, S, V]."""
    daxes = data_axes(mesh.axis_names) if mesh is not None else ()
    x = jnp.take(params["embed"], tokens, axis=0).astype(c.jdtype)
    if mesh is not None:
        x = shard(x, P(daxes, None, None))
    positions = jnp.arange(tokens.shape[1])
    x, _ = _run_layers(params, x, c, positions, mesh)
    x = rms_norm(x, params["final_norm"])
    return jnp.einsum("bld,dv->blv", x, params["lm_head"])


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def init_cache(c: TransformerConfig, batch: int, max_len: int):
    """Layer-stacked KV cache pytree (latent for MLA)."""
    dt = c.jdtype
    def group(n):
        if c.attn == "gqa":
            return (
                jnp.zeros((n, batch, c.n_kv_heads, max_len, c.d_head), dt),
                jnp.zeros((n, batch, c.n_kv_heads, max_len, c.d_head), dt),
            )
        return (
            jnp.zeros((n, batch, max_len, c.kv_lora), dt),
            jnp.zeros((n, batch, max_len, c.qk_rope), dt),
        )
    out = {}
    if c.n_dense_layers:
        out["dense"] = group(c.n_dense_layers)
    if c.n_moe_layers:
        out["moe"] = group(c.n_moe_layers)
    return out


def prefill(params, tokens, cache, c: TransformerConfig, mesh: Optional[Mesh] = None):
    """Fill the cache with a full prompt; returns (logits_last, cache)."""
    daxes = data_axes(mesh.axis_names) if mesh is not None else ()
    x = jnp.take(params["embed"], tokens, axis=0).astype(c.jdtype)
    if mesh is not None:
        x = shard(x, P(daxes, None, None))
    positions = jnp.arange(tokens.shape[1])
    x, new_caches = _run_layers(params, x, c, positions, mesh, caches=cache, pos=0)
    x = rms_norm(x[:, -1:], params["final_norm"])
    logits = jnp.einsum("bld,dv->blv", x, params["lm_head"])
    return logits, new_caches


def prefill_chunked(params, tokens, cache, c: TransformerConfig,
                    mesh: Optional[Mesh] = None, *, chunk: int = 8192):
    """Chunked prefill (Sarathi-style): stream the prompt through the cache
    in fixed chunks — bounds MoE dispatch buffers and attention working
    sets to O(chunk) instead of O(prompt). Returns (last_logits, cache)."""
    b, s = tokens.shape
    if s <= chunk:
        return prefill(params, tokens, cache, c, mesh)
    assert s % chunk == 0, "prompt length must be a chunk multiple"
    n_chunks = s // chunk
    toks = tokens.reshape(b, n_chunks, chunk).transpose(1, 0, 2)

    def step(carry, tc):
        cache, idx = carry
        pos = idx * chunk
        x = jnp.take(params["embed"], tc, axis=0).astype(c.jdtype)
        positions = pos + jnp.arange(chunk)
        x, new_cache = _run_layers(params, x, c, positions, mesh, caches=cache, pos=pos)
        x = rms_norm(x[:, -1:], params["final_norm"])
        logits = jnp.einsum("bld,dv->blv", x, params["lm_head"])
        return (new_cache, idx + 1), logits

    (cache, _), logits_all = jax.lax.scan(step, (cache, jnp.int32(0)), toks)
    return logits_all[-1], cache


def decode_step(params, token, cache, pos, c: TransformerConfig, mesh: Optional[Mesh] = None):
    """One decode step: token [B, 1] at position ``pos`` (traced scalar)."""
    x = jnp.take(params["embed"], token, axis=0).astype(c.jdtype)
    positions = pos + jnp.arange(1)
    x, new_caches = _run_layers(params, x, c, positions, mesh, caches=cache, pos=pos)
    x = rms_norm(x, params["final_norm"])
    logits = jnp.einsum("bld,dv->blv", x, params["lm_head"])
    return logits, new_caches
