"""Assigned GNN architectures: GatedGCN, GraphSAGE, MeshGraphNet, EquiformerV2.

All message passing is built from ``jnp.take`` (gather) +
``kernels.ops.segment_sum`` (scatter-reduce) over a padded edge list —
JAX has no native sparse message passing; this construction *is* part of
the system. Static-shape :class:`GraphData` carries node/edge padding
masks (padded edges point at the dummy node slot ``N``, dropped by the
segment reduction).

Distribution: edges and nodes shard over the data axes; weights are
replicated (they are tiny next to features). The NP-storage halo layout
from the DDSL core (each partition owns the full 1-hop neighborhood of
its centers) is the zero-communication alternative evaluated in §Perf.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.kernels import ops

from . import wigner

__all__ = [
    "GraphData",
    "GNNConfig",
    "init_params",
    "forward",
    "param_specs",
    "sage_minibatch_forward",
]


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class GraphData:
    """Padded graph batch. Edges with src/dst == n_nodes are padding."""

    x: jax.Array          # [N, F] node features
    src: jax.Array        # [E] int32
    dst: jax.Array        # [E] int32
    edge_attr: jax.Array  # [E, Fe] (zeros if unused)
    node_mask: jax.Array  # [N] bool
    edge_mask: jax.Array  # [E] bool
    positions: jax.Array  # [N, 3] (zeros for non-geometric graphs)

    def tree_flatten(self):
        return (self.x, self.src, self.dst, self.edge_attr, self.node_mask, self.edge_mask, self.positions), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    @property
    def n(self) -> int:
        return self.x.shape[0]


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str
    arch: str              # gatedgcn | graphsage | meshgraphnet | equiformer_v2
    n_layers: int
    d_hidden: int
    d_in: int
    d_out: int
    d_edge_in: int = 0
    aggregator: str = "mean"
    fanouts: Tuple[int, ...] = ()     # graphsage sampled mode
    mlp_layers: int = 2               # meshgraphnet
    l_max: int = 6                    # equiformer
    m_max: int = 2
    n_heads: int = 8
    dtype: str = "float32"
    remat: bool = True                # checkpoint each layer (bwd recompute)
    edge_chunk: int = 32768           # equiformer: bound per-chunk rotation/
                                      # message working set (lax.map)

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32


def _mlp_shapes(dims: Sequence[int], prefix: str) -> Dict[str, Tuple[int, ...]]:
    out = {}
    for i in range(len(dims) - 1):
        out[f"{prefix}_w{i}"] = (dims[i], dims[i + 1])
        out[f"{prefix}_b{i}"] = (dims[i + 1],)
    return out


def _mlp_apply(params, prefix: str, x: jax.Array, n: int, act=jax.nn.relu, norm: bool = False):
    for i in range(n):
        x = x @ params[f"{prefix}_w{i}"] + params[f"{prefix}_b{i}"]
        if i < n - 1:
            x = act(x)
    if norm:
        mu = x.mean(-1, keepdims=True)
        var = x.var(-1, keepdims=True)
        x = (x - mu) * jax.lax.rsqrt(var + 1e-6)
    return x


def _init(shapes: Dict[str, Tuple[int, ...]], key, dt) -> Dict:
    out = {}
    for i, (name, shp) in enumerate(sorted(shapes.items())):
        k = jax.random.fold_in(key, i)
        if len(shp) == 1:  # all 1-D params here are biases
            out[name] = jnp.zeros(shp, dt)
        else:
            out[name] = (jax.random.normal(k, shp, jnp.float32) / np.sqrt(shp[0])).astype(dt)
    return out


def _shard_hidden(h):
    """Pin node tensors to row-sharding over *all* mesh axes.

    Without this, GSPMD resolves edge gathers by replicating every [N, d]
    intermediate on every device (§Perf iteration: 59 GiB/dev on
    gatedgcn/ogb_products — 90+ live full-N copies). With the constraint,
    only the transient all-gather feeding each gather is full-N."""
    import jax as _jax
    from jax.sharding import PartitionSpec as _P

    try:
        am = _jax.typeof(h).sharding.mesh
    except Exception:
        return h
    names = tuple(getattr(am, "axis_names", ()))
    if not names:
        return h
    total = int(np.prod([am.shape[a] for a in names]))
    if h.shape[0] % max(total, 1) != 0:
        return h
    spec = _P(names, *([None] * (h.ndim - 1)))
    try:
        return _jax.lax.with_sharding_constraint(h, spec)
    except (ValueError, RuntimeError):
        return h


def _shard_edge(x):
    """Row-shard edge tensors over all mesh axes (same rationale)."""
    return _shard_hidden(x)


# ---------------------------------------------------------------------------
# Distributed gather / scatter (explicit shard_map locality)
#
# GSPMD resolves cross-shard gathers by replicating node tensors on every
# device (measured: 59 GiB/dev on gatedgcn/ogb, 43 TiB/dev on
# equiformer/ogb). These primitives make the data movement explicit:
#
# variant A (small feature tensors): all-gather the node table once per
#   call (one transient full-N buffer), take locally, psum-scatter partial
#   segment sums back to node shards;
# variant B (channel-split, EquiformerV2): the node table is exchanged to
#   (data-sharded nodes × model-sharded channels) before the all-gather, so
#   the transient is [N, dim, d/TP] — 16× smaller; edges end up sharded
#   over every axis in standard block order (all_to_all block layout
#   matches the all-axes sharding exactly).
# ---------------------------------------------------------------------------

def _mesh_axes(mesh):
    return tuple(mesh.axis_names)


def _gather_rows(mesh, h, idx):
    """out[i] = h[idx[i]] with h node-sharded and idx edge-sharded (all axes)."""
    from jax.sharding import PartitionSpec as _P

    axes = _mesh_axes(mesh)
    rest = (None,) * (h.ndim - 1)

    def body(h_loc, idx_loc):
        h_full = jax.lax.all_gather(h_loc, axes, axis=0, tiled=True)
        return jnp.take(h_full, idx_loc, axis=0)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(_P(axes, *rest), _P(axes)),
        out_specs=_P(axes, *rest), check_vma=False,
    )(h, idx)


def _gather_rows_cs(mesh, h, idx):
    """Channel-split gather: transient is [N, ..., d/TP] instead of full d."""
    from jax.sharding import PartitionSpec as _P

    axes = _mesh_axes(mesh)
    if "model" not in axes or h.shape[-1] % mesh.shape["model"] != 0:
        return _gather_rows(mesh, h, idx)
    daxes = tuple(a for a in axes if a != "model")
    rest = (None,) * (h.ndim - 1)
    ch_axis = h.ndim - 1

    def body(h_loc, idx_loc):
        # [N/G, ..., d] → [N/(pd), ..., d/M]: trade node rows for channels
        h_cs = jax.lax.all_to_all(h_loc, "model", split_axis=ch_axis, concat_axis=0, tiled=True)
        h_full = jax.lax.all_gather(h_cs, daxes, axis=0, tiled=True)   # [N, ..., d/M]
        rows = jnp.take(h_full, idx_loc, axis=0)                       # [E/(pd), ..., d/M]
        # split my edge rows across model peers, concat channels back
        return jax.lax.all_to_all(rows, "model", split_axis=0, concat_axis=ch_axis, tiled=True)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(_P(axes, *rest), _P(daxes)),   # idx replicated over 'model'
        out_specs=_P(axes, *rest), check_vma=False,
    )(h, idx)


def _scatter_sum(mesh, data, seg, n, backend):
    """Segment-sum with explicit partial-sums + psum-scatter to node shards."""
    from jax.sharding import PartitionSpec as _P

    axes = _mesh_axes(mesh)
    rest = (None,) * (data.ndim - 1)

    def body(d_loc, s_loc):
        part = ops.segment_sum(d_loc, s_loc, n, backend=backend)       # full N
        return jax.lax.psum_scatter(part, axes, scatter_dimension=0, tiled=True)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(_P(axes, *rest), _P(axes)),
        out_specs=_P(axes, *rest), check_vma=False,
    )(data, seg)


def _scatter_sum_cs(mesh, data, seg, n, backend):
    """Channel-split scatter: partial sums are [N, d/TP] instead of full d."""
    from jax.sharding import PartitionSpec as _P

    axes = _mesh_axes(mesh)
    if "model" not in axes or data.shape[-1] % mesh.shape["model"] != 0:
        return _scatter_sum(mesh, data, seg, n, backend)
    daxes = tuple(a for a in axes if a != "model")
    rest = (None,) * (data.ndim - 1)
    ch_axis = data.ndim - 1

    def body(d_loc, s_loc):
        # edges → (group edges × channel shard)
        d_cs = jax.lax.all_to_all(d_loc, "model", split_axis=ch_axis, concat_axis=0, tiled=True)
        part = ops.segment_sum(d_cs, s_loc, n, backend=backend)        # [N, d/M]
        part = jax.lax.psum_scatter(part, daxes, scatter_dimension=0, tiled=True)
        # nodes → (all-axes nodes × full channels)
        return jax.lax.all_to_all(part, "model", split_axis=0, concat_axis=ch_axis, tiled=True)

    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(_P(axes, *rest), _P(daxes)),   # seg replicated over 'model'
        out_specs=_P(axes, *rest), check_vma=False,
    )(data, seg)


def _segment_mean(data, seg, n, backend, mesh=None):
    if mesh is not None:
        s = _scatter_sum(mesh, data, seg, n, backend)
        cnt = _scatter_sum(mesh, jnp.ones((data.shape[0], 1), data.dtype), seg, n, backend)
        return s / jnp.maximum(cnt, 1.0)
    s = ops.segment_sum(data, seg, n, backend=backend)
    cnt = ops.segment_sum(jnp.ones((data.shape[0], 1), data.dtype), seg, n, backend=backend)
    return s / jnp.maximum(cnt, 1.0)


def _take_rows(mesh, h, idx, *, cs=False):
    if mesh is None:
        return jnp.take(h, idx, axis=0)
    return _gather_rows_cs(mesh, h, idx) if cs else _gather_rows(mesh, h, idx)


def _seg_sum(mesh, data, seg, n, backend, *, cs=False):
    if mesh is None:
        return ops.segment_sum(data, seg, n, backend=backend)
    if cs:
        return _scatter_sum_cs(mesh, data, seg, n, backend)
    return _scatter_sum(mesh, data, seg, n, backend)


# ---------------------------------------------------------------------------
# GatedGCN  [arXiv:1711.07553 / benchmarking-gnns config]
# ---------------------------------------------------------------------------

def _gatedgcn_shapes(c: GNNConfig) -> Dict:
    d = c.d_hidden
    shapes = {"embed_w": (c.d_in, d), "embed_b": (d,), "out_w": (d, c.d_out), "out_b": (c.d_out,)}
    if c.d_edge_in:
        shapes.update({"eembed_w": (c.d_edge_in, d), "eembed_b": (d,)})
    for i in range(c.n_layers):
        for nm in ("A", "B", "C", "U", "V"):
            shapes[f"l{i}_{nm}"] = (d, d)
    return shapes


def _gatedgcn_forward(params, g: GraphData, c: GNNConfig, backend, mesh=None):
    n = g.n
    h = g.x.astype(c.jdtype) @ params["embed_w"] + params["embed_b"]
    e = (
        g.edge_attr.astype(c.jdtype) @ params["eembed_w"] + params["eembed_b"]
        if c.d_edge_in
        else jnp.zeros((g.src.shape[0], c.d_hidden), h.dtype)
    )
    seg_dst = jnp.where(g.edge_mask, g.dst, n)

    def layer(i, h, e):
        hs = _take_rows(mesh, h, jnp.clip(g.src, 0, n - 1))
        hd = _take_rows(mesh, h, jnp.clip(g.dst, 0, n - 1))
        e_new = hd @ params[f"l{i}_A"] + hs @ params[f"l{i}_B"] + e @ params[f"l{i}_C"]
        eta = jax.nn.sigmoid(e_new)
        msg = eta * (hs @ params[f"l{i}_V"])
        agg = _seg_sum(mesh, msg, seg_dst, n, backend)
        den = _seg_sum(mesh, eta, seg_dst, n, backend)
        h_new = h @ params[f"l{i}_U"] + agg / (den + 1e-6)
        return _shard_hidden(h + jax.nn.relu(h_new)), e + jax.nn.relu(e_new)

    for i in range(c.n_layers):
        fn = jax.checkpoint(lambda hh, ee, i=i: layer(i, hh, ee), prevent_cse=False) if c.remat else (lambda hh, ee, i=i: layer(i, hh, ee))
        h, e = fn(h, e)
    return h @ params["out_w"] + params["out_b"]


# ---------------------------------------------------------------------------
# GraphSAGE (mean aggregator)  [arXiv:1706.02216]
# ---------------------------------------------------------------------------

def _graphsage_shapes(c: GNNConfig) -> Dict:
    shapes = {}
    dims = [c.d_in] + [c.d_hidden] * (c.n_layers - 1) + [c.d_out]
    for i in range(c.n_layers):
        shapes[f"l{i}_self"] = (dims[i], dims[i + 1])
        shapes[f"l{i}_neigh"] = (dims[i], dims[i + 1])
        shapes[f"l{i}_b"] = (dims[i + 1],)
    return shapes


def _graphsage_forward(params, g: GraphData, c: GNNConfig, backend, mesh=None):
    n = g.n
    h = g.x.astype(c.jdtype)
    seg_dst = jnp.where(g.edge_mask, g.dst, n)

    def layer(i, h):
        hs = _take_rows(mesh, h, jnp.clip(g.src, 0, n - 1))
        agg = _segment_mean(hs, seg_dst, n, backend, mesh)
        h = h @ params[f"l{i}_self"] + agg @ params[f"l{i}_neigh"] + params[f"l{i}_b"]
        if i < c.n_layers - 1:
            h = jax.nn.relu(h)
            h = h / (jnp.linalg.norm(h, axis=-1, keepdims=True) + 1e-6)
        return _shard_hidden(h)

    for i in range(c.n_layers):
        fn = jax.checkpoint(lambda hh, i=i: layer(i, hh), prevent_cse=False) if c.remat else (lambda hh, i=i: layer(i, hh))
        h = fn(h)
    return h


def sage_minibatch_forward(params, feats: Sequence[jax.Array], c: GNNConfig):
    """Sampled-neighborhood forward (fixed fanouts → dense reshape-mean).

    ``feats[k]``: features of the k-hop frontier, [B·Πf₁..f_k, d_in].
    """
    hs = list(feats)
    for i in range(c.n_layers):
        new_hs = []
        for depth in range(len(hs) - 1):
            fanout = c.fanouts[depth]
            parent = hs[depth]
            child = hs[depth + 1].reshape(parent.shape[0], fanout, -1)
            agg = child.mean(axis=1)
            out = parent @ params[f"l{i}_self"] + agg @ params[f"l{i}_neigh"] + params[f"l{i}_b"]
            if i < c.n_layers - 1:
                out = jax.nn.relu(out)
                out = out / (jnp.linalg.norm(out, axis=-1, keepdims=True) + 1e-6)
            new_hs.append(out)
        hs = new_hs
    return hs[0]


# ---------------------------------------------------------------------------
# MeshGraphNet  [arXiv:2010.03409]
# ---------------------------------------------------------------------------

def _mgn_shapes(c: GNNConfig) -> Dict:
    d = c.d_hidden
    shapes = {}
    shapes.update(_mlp_shapes([c.d_in, d, d], "enc_n"))
    shapes.update(_mlp_shapes([max(c.d_edge_in, 1), d, d], "enc_e"))
    for i in range(c.n_layers):
        shapes.update(_mlp_shapes([3 * d, d, d], f"p{i}_edge"))
        shapes.update(_mlp_shapes([2 * d, d, d], f"p{i}_node"))
    shapes.update(_mlp_shapes([d, d, c.d_out], "dec"))
    return shapes


def _mgn_forward(params, g: GraphData, c: GNNConfig, backend, mesh=None):
    n = g.n
    h = _mlp_apply(params, "enc_n", g.x.astype(c.jdtype), 2, norm=True)
    ea = g.edge_attr.astype(c.jdtype) if c.d_edge_in else jnp.ones((g.src.shape[0], 1), h.dtype)
    e = _mlp_apply(params, "enc_e", ea, 2, norm=True)
    seg_dst = jnp.where(g.edge_mask, g.dst, n)

    def layer(i, h, e):
        hs = _take_rows(mesh, h, jnp.clip(g.src, 0, n - 1))
        hd = _take_rows(mesh, h, jnp.clip(g.dst, 0, n - 1))
        e = e + _mlp_apply(params, f"p{i}_edge", jnp.concatenate([e, hs, hd], -1), 2, norm=True)
        agg = _seg_sum(mesh, e, seg_dst, n, backend)
        h = h + _mlp_apply(params, f"p{i}_node", jnp.concatenate([h, agg], -1), 2, norm=True)
        return _shard_hidden(h), e

    for i in range(c.n_layers):
        fn = jax.checkpoint(lambda hh, ee, i=i: layer(i, hh, ee), prevent_cse=False) if c.remat else (lambda hh, ee, i=i: layer(i, hh, ee))
        h, e = fn(h, e)
    return _mlp_apply(params, "dec", h, 2)


# ---------------------------------------------------------------------------
# EquiformerV2 (eSCN SO(2) convolutions)  [arXiv:2306.12059]
# ---------------------------------------------------------------------------

def _eqv2_m_indices(l_max: int, m_max: int):
    """Coefficient indices with |m| ≤ m_max, grouped by m."""
    groups = {}
    off = 0
    for l in range(l_max + 1):
        for m in range(-l, l + 1):
            if abs(m) <= m_max:
                groups.setdefault(m, []).append(off + m + l)
        off += 2 * l + 1
    return groups


def _eqv2_shapes(c: GNNConfig) -> Dict:
    d = c.d_hidden
    groups = _eqv2_m_indices(c.l_max, c.m_max)
    shapes = {
        "embed_w": (c.d_in, d), "embed_b": (d,),
        "out_w": (d, c.d_out), "out_b": (c.d_out,),
    }
    for i in range(c.n_layers):
        for m, idxs in groups.items():
            if m < 0:
                continue
            nl = len(idxs)
            # SO(2) linear: mixes l-channels within fixed m (+ pairs for m>0)
            shapes[f"l{i}_so2_m{m}_r"] = (nl * d, nl * d)
            if m > 0:
                shapes[f"l{i}_so2_m{m}_i"] = (nl * d, nl * d)
        shapes.update(_mlp_shapes([d, d, c.n_heads], f"l{i}_alpha"))
        shapes.update(_mlp_shapes([d, d, d], f"l{i}_update"))
        shapes[f"l{i}_gate_w"] = (d, c.l_max)
        shapes[f"l{i}_gate_b"] = (c.l_max,)
    return shapes


def _so2_mix(params, i, edge_f, groups, d):
    """SO(2)-restricted linear mixing per |m| (the eSCN O(L³) trick)."""
    out_f = jnp.zeros_like(edge_f)
    for m in sorted(k for k in groups if k >= 0):
        ip = groups[m]
        wr = params[f"l{i}_so2_m{m}_r"]
        xp = edge_f[:, ip, :].reshape(edge_f.shape[0], -1)
        if m == 0:
            out_f = out_f.at[:, ip, :].set((xp @ wr).reshape(-1, len(ip), d))
        else:
            im = groups[-m]
            wi = params[f"l{i}_so2_m{m}_i"]
            xm = edge_f[:, im, :].reshape(edge_f.shape[0], -1)
            yp = xp @ wr - xm @ wi
            ym = xp @ wi + xm @ wr
            out_f = out_f.at[:, ip, :].set(yp.reshape(-1, len(ip), d))
            out_f = out_f.at[:, im, :].set(ym.reshape(-1, len(ip), d))
    return out_f


def _eqv2_forward(params, g: GraphData, c: GNNConfig, backend, mesh=None):
    """Structurally-faithful eSCN stack, chunked over edges.

    Per layer (both passes stream edge chunks through a lax.scan so the
    per-device working set is [edge_chunk, (l+1)², d] instead of the full
    edge dimension — the §Perf iteration that brought ogb_products from
    ~1.8 TiB/dev to single-digit GiB):
      pass 1: attention logits from the invariant channel of the SO(2)
              conv (only the m=0 rows of the rotated features are needed);
      softmax normalization per destination (segment max/sum);
      pass 2: full SO(2) messages, rotated back, weighted, partial
              segment-sums accumulated across chunks.
    """
    n = g.n
    dim = wigner.sh_basis_size(c.l_max)
    d = c.d_hidden
    groups = _eqv2_m_indices(c.l_max, c.m_max)
    m0 = groups[0]

    h0 = g.x.astype(c.jdtype) @ params["embed_w"] + params["embed_b"]  # invariant
    feat = jnp.zeros((n, dim, d), h0.dtype).at[:, 0, :].set(h0)

    vec = jnp.take(g.positions, jnp.clip(g.dst, 0, n - 1), axis=0) - jnp.take(
        g.positions, jnp.clip(g.src, 0, n - 1), axis=0
    )
    rot = wigner.edge_rotation(c.l_max, vec)                  # [E, dim, dim]
    seg_dst = jnp.where(g.edge_mask, g.dst, n)

    e_total = g.src.shape[0]
    shard_mult = 1
    if mesh is not None:
        for v in mesh.shape.values():
            shard_mult *= v
    n_chunks = 1
    while (
        e_total % (n_chunks * 2) == 0
        and e_total // (n_chunks * 2) >= max(c.edge_chunk, shard_mult)
        and (e_total // (n_chunks * 2)) % shard_mult == 0
    ):
        n_chunks *= 2
    ck = e_total // n_chunks
    src_r = jnp.clip(g.src, 0, n - 1).reshape(n_chunks, ck)
    dst_r = jnp.clip(g.dst, 0, n - 1).reshape(n_chunks, ck)
    seg_r = seg_dst.reshape(n_chunks, ck)
    mask_r = g.edge_mask.reshape(n_chunks, ck)
    rot_r = rot.reshape(n_chunks, ck, dim, dim)

    def layer(i, feat):
        # ---- pass 1: attention logits (m=0 rows only) --------------------
        def alpha_chunk(xs):
            src_f, rot_c = xs                                      # [ck, dim, d]
            rot_m0 = rot_c[:, m0, :]                               # [ck, n_l0, dim]
            ef0 = jnp.einsum("eij,ejc->eic", rot_m0, src_f)        # m=0 rows
            wr = params[f"l{i}_so2_m0_r"]
            out0 = (ef0.reshape(ck, -1) @ wr).reshape(ck, len(m0), d)[:, 0, :]
            return _mlp_apply(params, f"l{i}_alpha", out0, 2)      # [ck, H]

        src_feat = _take_rows(mesh, feat, jnp.clip(g.src, 0, n - 1), cs=True)
        src_feat_r = src_feat.reshape(n_chunks, ck, dim, d)
        alpha = jax.lax.map(alpha_chunk, (src_feat_r, rot_r)).reshape(e_total, -1)
        amax = jax.ops.segment_max(
            jnp.where(g.edge_mask[:, None], alpha, -jnp.inf), seg_dst, num_segments=n + 1
        )
        alpha = alpha - amax[jnp.clip(g.dst, 0, n - 1)]
        w = jnp.exp(jnp.where(g.edge_mask[:, None], alpha, -jnp.inf))
        den = _seg_sum(mesh, w, seg_dst, n, backend)
        w = w / jnp.maximum(den[jnp.clip(g.dst, 0, n - 1)], 1e-9)
        wh = w.mean(-1).reshape(n_chunks, ck)                      # head-avg gate

        # ---- pass 2: chunked messages, accumulated partial segment sums --
        def msg_chunk(agg, xs):
            src_f, seg_c, rot_c, w_c, mask_c = xs
            edge_f = jnp.einsum("eij,ejc->eic", rot_c, src_f)
            out_f = _so2_mix(params, i, edge_f, groups, d)
            msg = jnp.einsum("eji,ejc->eic", rot_c, out_f)         # back to global
            msg = msg * w_c[:, None, None].astype(msg.dtype) * mask_c[:, None, None]
            part = _seg_sum(mesh, msg.reshape(ck, -1), seg_c, n, backend, cs=True)
            return agg + part.astype(agg.dtype), 0

        agg0 = _shard_hidden(jnp.zeros((n, dim * d), feat.dtype))
        agg, _ = jax.lax.scan(msg_chunk, agg0, (src_feat_r, seg_r, rot_r, wh, mask_r))
        agg = agg.reshape(n, dim, d)

        # ---- gated update --------------------------------------------------
        inv = agg[:, 0, :]
        upd = _mlp_apply(params, f"l{i}_update", inv, 2)
        gates = jax.nn.sigmoid(inv @ params[f"l{i}_gate_w"] + params[f"l{i}_gate_b"])
        feat = feat.at[:, 0, :].add(upd)
        off = 1
        for l in range(1, c.l_max + 1):
            nl = 2 * l + 1
            feat = feat.at[:, off : off + nl, :].add(
                agg[:, off : off + nl, :] * gates[:, None, l - 1 : l]
            )
            off += nl
        return _shard_hidden(feat)

    for i in range(c.n_layers):
        fn = jax.checkpoint(lambda f_, i=i: layer(i, f_), prevent_cse=False) if c.remat else (lambda f_, i=i: layer(i, f_))
        feat = fn(feat)
    return feat[:, 0, :] @ params["out_w"] + params["out_b"]


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

_SHAPES = {
    "gatedgcn": _gatedgcn_shapes,
    "graphsage": _graphsage_shapes,
    "meshgraphnet": _mgn_shapes,
    "equiformer_v2": _eqv2_shapes,
}

_FORWARD = {
    "gatedgcn": _gatedgcn_forward,
    "graphsage": _graphsage_forward,
    "meshgraphnet": _mgn_forward,
    "equiformer_v2": _eqv2_forward,
}


def init_params(c: GNNConfig, key: jax.Array) -> Dict:
    return _init(_SHAPES[c.arch](c), key, c.jdtype)


def forward(params, g: GraphData, c: GNNConfig, *, backend: str = "ref", mesh=None) -> jax.Array:
    return _FORWARD[c.arch](params, g, c, backend, mesh)


def param_specs(c: GNNConfig, mesh_axes: Sequence[str]) -> Dict:
    """GNN weights are small → replicated; features/edges shard over data."""
    shapes = _SHAPES[c.arch](c)
    return {k: P(*([None] * len(v))) for k, v in shapes.items()}


def graph_specs(mesh_axes: Sequence[str]) -> GraphData:
    """PartitionSpecs for GraphData: nodes/edges sharded over every axis."""
    all_ax = tuple(mesh_axes)
    return GraphData(
        x=P(all_ax, None),
        src=P(all_ax),
        dst=P(all_ax),
        edge_attr=P(all_ax, None),
        node_mask=P(all_ax),
        edge_mask=P(all_ax),
        positions=P(all_ax, None),
    )
