"""Assigned-architecture model zoo.

Families:
- ``transformer``  — dense GQA / MLA / MoE LMs (5 assigned archs)
- ``gnn``          — GatedGCN, GraphSAGE, MeshGraphNet, EquiformerV2
- ``dlrm``         — DLRM-RM2 (embedding bags + dot interaction)

Every model exposes ``init_params``, ``forward`` (+ ``decode_step`` /
``prefill`` for LMs), ``param_specs`` (PartitionSpec pytree) and a
``train_step``/``serve_step`` builder used by the launcher and dry-run.
"""
