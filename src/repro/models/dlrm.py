"""DLRM-RM2 [arXiv:1906.00091]: embedding bags → dot interaction → MLPs.

JAX has no native EmbeddingBag — lookups are ``jnp.take`` +
``segment_sum`` (or the Pallas ``embedding_bag`` kernel). The 26 sparse
tables are stacked ``[n_sparse, rows, dim]`` and *model*-sharded on the
rows dim; lookups against a row-sharded table lower to a collective
gather — the same access pattern as the distributed DDSL probe, and the
target of one §Perf iteration.

Shapes: train (batch 65536), serve_p99 (512), serve_bulk (262144), and
retrieval_cand (1 query × 10⁶ candidates — batched dot, never a loop).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["DLRMConfig", "init_params", "forward", "retrieval_scores", "param_specs"]


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    rows_per_table: int = 1_000_000
    bot_mlp: Tuple[int, ...] = (512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    multi_hot: int = 1           # lookups per field (bag size)
    dtype: str = "float32"

    @property
    def jdtype(self):
        return jnp.bfloat16 if self.dtype == "bfloat16" else jnp.float32

    @property
    def n_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2


def init_params(c: DLRMConfig, key: jax.Array) -> Dict:
    dt = c.jdtype
    params = {
        "tables": (
            jax.random.normal(jax.random.fold_in(key, 0), (c.n_sparse, c.rows_per_table, c.embed_dim), jnp.float32)
            / np.sqrt(c.embed_dim)
        ).astype(dt)
    }
    dims = (c.n_dense,) + c.bot_mlp
    for i in range(len(dims) - 1):
        k = jax.random.fold_in(key, 10 + i)
        params[f"bot_w{i}"] = (jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32) / np.sqrt(dims[i])).astype(dt)
        params[f"bot_b{i}"] = jnp.zeros((dims[i + 1],), dt)
    top_in = c.n_interact + c.bot_mlp[-1]
    dims = (top_in,) + c.top_mlp
    for i in range(len(dims) - 1):
        k = jax.random.fold_in(key, 30 + i)
        params[f"top_w{i}"] = (jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32) / np.sqrt(dims[i])).astype(dt)
        params[f"top_b{i}"] = jnp.zeros((dims[i + 1],), dt)
    return params


def param_specs(c: DLRMConfig, mesh_axes: Sequence[str]) -> Dict:
    mdl = "model" if "model" in mesh_axes else None
    specs = {"tables": P(None, mdl, None)}  # rows model-sharded
    dims = (c.n_dense,) + c.bot_mlp
    for i in range(len(dims) - 1):
        specs[f"bot_w{i}"] = P(None, None)
        specs[f"bot_b{i}"] = P(None)
    dims = (c.n_interact + c.bot_mlp[-1],) + c.top_mlp
    for i in range(len(dims) - 1):
        specs[f"top_w{i}"] = P(None, None)
        specs[f"top_b{i}"] = P(None)
    return specs


def _mlp(params, prefix: str, x: jax.Array, n: int, final_act=None):
    for i in range(n):
        x = x @ params[f"{prefix}_w{i}"] + params[f"{prefix}_b{i}"]
        if i < n - 1:
            x = jax.nn.relu(x)
    return final_act(x) if final_act is not None else x


def _embedding_bags(params, sparse_ids: jax.Array, c: DLRMConfig) -> jax.Array:
    """sparse_ids: [B, n_sparse, multi_hot] → [B, n_sparse, dim].

    One-hot fields (multi_hot=1) reduce to a plain row gather; larger bags
    sum (the EmbeddingBag construction).
    """
    rows = jnp.take_along_axis(
        params["tables"][None],                                    # [1, F, V, D]
        sparse_ids.transpose(1, 0, 2).reshape(1, c.n_sparse, -1, 1),  # [1, F, B·H, 1]
        axis=2,
    )  # → [1, F, B·H, D]
    b = sparse_ids.shape[0]
    rows = rows[0].reshape(c.n_sparse, b, c.multi_hot, c.embed_dim)
    return rows.sum(axis=2).transpose(1, 0, 2)


def forward(params, dense: jax.Array, sparse_ids: jax.Array, c: DLRMConfig) -> jax.Array:
    """dense: [B, n_dense]; sparse_ids: [B, n_sparse, multi_hot] → logits [B]."""
    bot = _mlp(params, "bot", dense.astype(c.jdtype), len(c.bot_mlp))       # [B, D]
    emb = _embedding_bags(params, sparse_ids, c)                            # [B, F, D]
    feats = jnp.concatenate([bot[:, None, :], emb], axis=1)                 # [B, F+1, D]
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)                        # dot interaction
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    flat = inter[:, iu, ju]                                                 # [B, F(F-1)/2]
    top_in = jnp.concatenate([bot, flat], axis=-1)
    return _mlp(params, "top", top_in, len(c.top_mlp))[:, 0]


def retrieval_scores(params, dense: jax.Array, user_sparse: jax.Array,
                     candidate_ids: jax.Array, c: DLRMConfig) -> jax.Array:
    """Score one query against N candidates (retrieval_cand shape).

    The user side (dense + 25 sparse fields) is computed once; the last
    sparse field is swept over ``candidate_ids`` [N]. Batched — the
    interaction/top-MLP broadcast over candidates, never a loop.
    """
    n = candidate_ids.shape[0]
    bot = _mlp(params, "bot", dense.astype(c.jdtype), len(c.bot_mlp))       # [1, D]
    emb_user = _embedding_bags(params, user_sparse, c)                      # [1, F, D]
    cand = jnp.take(params["tables"][c.n_sparse - 1], candidate_ids, axis=0)  # [N, D]
    feats = jnp.concatenate([bot[:, None, :], emb_user], axis=1)            # [1, F+1, D]
    feats = jnp.broadcast_to(feats, (n,) + feats.shape[1:])
    feats = feats.at[:, -1, :].set(cand)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    f = feats.shape[1]
    iu, ju = jnp.triu_indices(f, k=1)
    flat = inter[:, iu, ju]
    top_in = jnp.concatenate([jnp.broadcast_to(bot, (n, bot.shape[-1])), flat], axis=-1)
    return _mlp(params, "top", top_in, len(c.top_mlp))[:, 0]
