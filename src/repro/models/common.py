"""Shared model substrate: norms, RoPE, MLPs, losses, sharding helpers."""

from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = [
    "rms_norm",
    "rope",
    "apply_rope",
    "swiglu",
    "cross_entropy",
    "shard",
    "data_axes",
    "DEFAULT_DTYPE",
]

DEFAULT_DTYPE = jnp.bfloat16


def data_axes(mesh_axes: Sequence[str]) -> Tuple[str, ...]:
    """The batch-parallel axes: ('pod', 'data') on multi-pod meshes."""
    return tuple(a for a in mesh_axes if a in ("pod", "data"))


def shard(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint that is a no-op outside jit/mesh contexts."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(dt)


def rope(positions: jax.Array, dim: int, theta: float = 1e4) -> Tuple[jax.Array, jax.Array]:
    """cos/sin tables for rotary embedding; positions [..., L]."""
    freqs = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., L, dim/2]
    return jnp.cos(angles), jnp.sin(angles)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: [..., L, D]; rotate pairs (even, odd)."""
    x1 = x[..., 0::2]
    x2 = x[..., 1::2]
    while cos.ndim < x1.ndim:
        cos = cos[None]
        sin = sin[None]
    out1 = x1 * cos - x2 * sin
    out2 = x1 * sin + x2 * cos
    return jnp.stack([out1, out2], axis=-1).reshape(x.shape).astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean token NLL in fp32; logits [..., V], labels [...] int32."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
    return jnp.mean(lse - ll)
